"""Noise-model and weight-clipping properties (paper eqs. 3–5, App. E.3),
plus the per-tile device model (``core.devices``): seeded determinism,
drift monotonicity, fault masks, the recalibration contract, fused≡unfused
per-tile parity on the kernel shape grid, and the one-deployment-one-
noise-instance eval contract.

Property tests skip (instead of breaking collection) when hypothesis is
absent — see tests/strategies.py / requirements-dev.txt.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import assert_adc_parity
from strategies import given, settings, st
from test_kernel_dispatch import EVAL, SHAPES_STRICT, _adc_lsb, _case

from repro.core import clipping, devices, noise
from repro.core.analog import (AnalogConfig, analog_linear,
                               apply_noise_instances, linear_labels,
                               noisy_matmul, sample_noise_instances)


@given(st.integers(0, 2**31 - 1), st.floats(0.005, 0.1))
@settings(max_examples=20, deadline=None)
def test_gaussian_noise_statistics(seed, gamma):
    key = jax.random.PRNGKey(seed)
    w = jax.random.normal(key, (256, 64)) * 0.1
    n = noise.gaussian_weight_noise(key, w, gamma)
    sigma_exp = gamma * np.abs(np.asarray(w)).max(axis=0)
    sigma_obs = np.asarray(n).std(axis=0)
    # per-channel std matches gamma * max|W_col| within sampling error
    assert np.allclose(sigma_obs, sigma_exp, rtol=0.35)


def test_pcm_sigma_polynomial_anchors():
    # noise floor at zero conductance, growth toward max
    s0 = float(noise.pcm_hermes_sigma(jnp.float32(0.0)))
    s100 = float(noise.pcm_hermes_sigma(jnp.float32(100.0)))
    assert s0 == pytest.approx(2.11, abs=1e-6)
    assert 7.0 < s100 < 9.0
    # monotone over most of the range (allow the fitted poly to wiggle)
    xs = np.linspace(0, 100, 101)
    ys = np.asarray(noise.pcm_hermes_sigma(jnp.asarray(xs, jnp.float32)))
    assert ys.min() >= 2.0


def test_pcm_noise_zero_weights_noiseless():
    key = jax.random.PRNGKey(0)
    w = jnp.zeros((32, 16)).at[0, 0].set(1.0)
    n = np.asarray(noise.pcm_hermes_noise(key, w))
    assert np.all(n[1:, :] == 0)
    assert np.all(n[:, 1:] == 0)
    assert n[0, 0] != 0


def test_pcm_noise_snr_ordering():
    """Bigger weights get more absolute noise but better relative SNR."""
    key = jax.random.PRNGKey(1)
    w = jnp.concatenate([jnp.full((4000, 1), 0.05), jnp.full((4000, 1), 1.0)],
                        axis=0)
    n = np.asarray(noise.pcm_hermes_noise(key, w))
    std_small = n[:4000].std()
    std_big = n[4000:].std()
    assert std_big > std_small                 # absolute noise grows
    assert std_big / 1.0 < std_small / 0.05    # relative noise shrinks


@given(st.integers(0, 2**31 - 1), st.floats(1.5, 4.0))
@settings(max_examples=20, deadline=None)
def test_clip_weight_bound(seed, alpha):
    key = jax.random.PRNGKey(seed)
    w = jax.random.normal(key, (128, 32))
    wc = np.asarray(clipping.clip_weight(w, alpha))
    std = np.asarray(w).std(axis=0)
    assert np.all(np.abs(wc) <= alpha * std + 1e-5)
    # clipping contracts: repeated clips keep shrinking toward 0 but each
    # pass moves less than the first (std shrinks monotonically)
    wcc = np.asarray(clipping.clip_weight(jnp.asarray(wc), alpha))
    assert np.abs(wcc).max() <= np.abs(wc).max() + 1e-6
    assert np.asarray(wcc).std() <= np.asarray(wc).std() + 1e-6


def test_clipping_reduces_kurtosis():
    key = jax.random.PRNGKey(2)
    # heavy-tailed weights (outliers)
    w = jax.random.t(key, df=3.0, shape=(4096,)).reshape(256, 16)
    k_before = float(clipping.kurtosis(w))
    wc = clipping.clip_weight(w, 3.0)
    k_after = float(clipping.kurtosis(wc))
    assert k_after < k_before          # Fig. 6b mechanism


def test_clip_tree_only_touches_analog_weights():
    params = {"a": {"kernel": jnp.ones((4, 4)) * 10,
                    "input_range": jnp.ones((1,))},
              "n": {"scale": jnp.ones((4,)) * 10}}
    labels = {"a": {"kernel": "analog_weight", "input_range": "input_range"},
              "n": {"scale": "digital"}}
    out = clipping.clip_tree(params, labels, alpha=2.0)
    assert float(jnp.max(out["n"]["scale"])) == 10.0
    assert float(jnp.max(out["a"]["kernel"])) < 10.0 or \
        float(jnp.std(params["a"]["kernel"])) == 0.0


def test_noisy_matmul_backward_uses_clean_weights():
    key = jax.random.PRNGKey(3)
    x = jax.random.normal(key, (8, 16))
    w = jax.random.normal(key, (16, 4))
    big_noise = jnp.ones_like(w) * 100.0

    def f(x, w):
        return jnp.sum(noisy_matmul(x, w, big_noise))

    gx, gw = jax.grad(f, argnums=(0, 1))(x, w)
    # dx must be g @ w.T with CLEAN w (noise-free backward, paper §3.1)
    expect_gx = jnp.ones((8, 4)) @ w.T
    np.testing.assert_allclose(np.asarray(gx), np.asarray(expect_gx),
                               rtol=1e-5)
    expect_gw = x.T @ jnp.ones((8, 4))
    np.testing.assert_allclose(np.asarray(gw), np.asarray(expect_gw),
                               rtol=1e-5)


# ---------------------------------------------------------------------------
# honest-config validation (no silent placebos)
# ---------------------------------------------------------------------------

def test_validate_noise_config_rejects_placebos():
    noise.validate_noise_config("none")
    noise.validate_noise_config("hw")
    noise.validate_noise_config("gaussian", 0.05)
    with pytest.raises(ValueError, match="placebo"):
        noise.validate_noise_config("gaussian", 0.0)
    with pytest.raises(ValueError, match=">= 0"):
        noise.validate_noise_config("gaussian", -0.1)
    with pytest.raises(ValueError, match=">= 0"):
        noise.validate_noise_config("hw", -1.0)
    with pytest.raises(ValueError, match="unknown"):
        noise.validate_noise_config("pcm_but_typod")
    with pytest.raises(ValueError, match="placebo"):
        noise.apply_eval_noise(jax.random.PRNGKey(0),
                               jnp.ones((4, 4)), "gaussian", 0.0)


def test_validate_device_config_rejects_nonsense():
    devices.validate_config(devices.DeviceConfig())
    with pytest.raises(ValueError, match="tile dims"):
        devices.validate_config(devices.DeviceConfig(tile_k=0))
    with pytest.raises(ValueError, match="sigma_gain"):
        devices.validate_config(devices.DeviceConfig(sigma_gain=-0.1))
    with pytest.raises(ValueError, match="probability"):
        devices.validate_config(devices.DeviceConfig(p_stuck_col=1.5))
    with pytest.raises(ValueError, match="t0"):
        devices.validate_config(devices.DeviceConfig(t0=0.0))


# ---------------------------------------------------------------------------
# one deployment = one sampled noise instance (eval harness contract)
# ---------------------------------------------------------------------------

def _toy_site(seed=0, k=64, n=32):
    from repro.core.analog import init_linear
    p = init_linear(jax.random.PRNGKey(seed), k, n, use_bias=True)
    return {"l": p}, {"l": linear_labels(p)}


def test_noise_instances_scale_with_gamma():
    """A gaussian deployment instance is a *unit* term: rescaling gamma
    rescales the same chip instead of re-drawing a new one (the Fig. 3
    sweep contract)."""
    params, labels = _toy_site()
    inst = sample_noise_instances(params, labels, jax.random.PRNGKey(5),
                                  "gaussian")
    w = np.asarray(params["l"]["kernel"])
    d1 = np.asarray(apply_noise_instances(params, labels, inst, "gaussian",
                                          0.05)["l"]["kernel"]) - w
    d2 = np.asarray(apply_noise_instances(params, labels, inst, "gaussian",
                                          0.10)["l"]["kernel"]) - w
    assert np.abs(d1).max() > 0
    np.testing.assert_allclose(d2, 2.0 * d1, rtol=1e-5, atol=1e-7)


def test_noise_instance_reuse_is_bitwise():
    """Applying the same instance twice perturbs identically — the
    regression the fig3 sweep fix depends on (one chip across the whole
    gamma curve, not a fresh draw per evaluate() call)."""
    params, labels = _toy_site(seed=3)
    inst = sample_noise_instances(params, labels, jax.random.PRNGKey(9),
                                  "hw")
    a = apply_noise_instances(params, labels, inst, "hw")
    b = apply_noise_instances(params, labels, inst, "hw")
    assert np.array_equal(np.asarray(a["l"]["kernel"]),
                          np.asarray(b["l"]["kernel"]))


def test_deployment_instances_pin_chips_across_evaluate_calls():
    """evaluate(..., instances=...) must reproduce bitwise-identical
    results call to call — the per-call re-sampling bug the harness fix
    removes."""
    from repro.eval.harness import NoiseSpec, deployment_instances, evaluate
    params, labels = _toy_site(seed=7)
    tasks = {"sum": lambda p, cfg, acfg:
             float(jnp.sum(p["l"]["kernel"]))}
    inst = deployment_instances(params, labels, "gaussian", seeds=3)
    spec = NoiseSpec("gaussian", 0.08)
    r1 = evaluate(params, labels, None, None, tasks, spec, seeds=3,
                  instances=inst)
    r2 = evaluate(params, labels, None, None, tasks, spec, seeds=3,
                  instances=inst)
    assert r1["sum"]["runs"] == r2["sum"]["runs"]
    with pytest.raises(ValueError, match="deployment instances"):
        evaluate(params, labels, None, None, tasks, spec, seeds=5,
                 instances=inst)


# ---------------------------------------------------------------------------
# per-tile device state: sampling, drift, faults, recalibration
# ---------------------------------------------------------------------------

_DCFG = devices.DeviceConfig(tile_k=32, tile_n=32, sigma_gain=0.03,
                             nu_median=0.08, nu_sigma=0.3,
                             sigma_offset=0.01, p_stuck_col=0.05,
                             p_dead_tile=0.05)


def _device_site(m=4, k=96, n=64, seed=11, dcfg=_DCFG):
    p, x = _case(m, k, n, key=seed)
    params, labels = {"l": p}, {"l": linear_labels(p)}
    dp = devices.attach_device_state(params, labels,
                                     jax.random.PRNGKey(seed), dcfg)
    return dp["l"], p, x


def test_device_state_seeded_determinism():
    params, labels = _toy_site()
    a = devices.attach_device_state(params, labels, jax.random.PRNGKey(4),
                                    _DCFG)
    b = devices.attach_device_state(params, labels, jax.random.PRNGKey(4),
                                    _DCFG)
    c = devices.attach_device_state(params, labels, jax.random.PRNGKey(5),
                                    _DCFG)
    for leaf in ("gain", "nu", "off", "dead", "stuck"):
        assert np.array_equal(np.asarray(a["l"]["device"][leaf]),
                              np.asarray(b["l"]["device"][leaf]))
    assert not np.array_equal(np.asarray(a["l"]["device"]["gain"]),
                              np.asarray(c["l"]["device"]["gain"]))
    assert devices.has_device_state(a) and not devices.has_device_state(
        params)


def test_drift_monotonically_degrades_tile_scale():
    """mean |scale - 1| over live tiles is nondecreasing as the clock
    advances (conductance decays along every tile's power law)."""
    params, labels = _toy_site(seed=2)
    dp = devices.attach_device_state(params, labels, jax.random.PRNGKey(0),
                                     _DCFG)
    errs = []
    for h in (0.0, 1.0, 6.0, 48.0, 168.0):
        aged = devices.advance(dp, h) if h else dp
        errs.append(devices.health(aged)["mean_scale_err"])
    assert all(b >= a - 1e-9 for a, b in zip(errs, errs[1:]))
    assert errs[-1] > errs[0]
    # the clock is pure accumulation: advance(a+b) == advance(a)+advance(b)
    two_step = devices.advance(devices.advance(dp, 5.0), 43.0)
    assert np.allclose(
        np.asarray(two_step["l"]["device"]["t"]),
        np.asarray(devices.advance(dp, 48.0)["l"]["device"]["t"]))


def test_stuck_and_dead_fault_masks():
    """stuck-at-Gmin columns read exactly 0, stuck-at-Gmax columns pin at
    the pristine column absmax, dead tiles zero their whole span."""
    dcfg = dataclasses.replace(_DCFG, p_stuck_col=0.3, p_dead_tile=0.6)
    dev_p, p, _ = _device_site(dcfg=dcfg)
    dev = dev_p["device"]
    w = p["kernel"]
    bound = jnp.ones(w.shape[-1], jnp.float32)
    w_eff, col_off = devices.corrupt_weights(w, dev, bound)
    w_eff = np.asarray(w_eff)
    stuck = np.asarray(dev["stuck"])
    colmax = np.abs(np.asarray(w)).max(axis=0)
    assert (stuck == 1).any() and (stuck == 2).any()
    assert np.all(w_eff[:, stuck == 1] == 0.0)
    np.testing.assert_array_equal(
        w_eff[:, stuck == 2], np.broadcast_to(colmax[stuck == 2],
                                              w_eff[:, stuck == 2].shape))
    # dead tiles read 0 wherever no stuck-at-Gmax column overrides them
    dead = np.asarray(dev["dead"])
    assert dead.any()
    tk, tn = dcfg.tile_k, dcfg.tile_n
    for ti, tj in zip(*np.nonzero(dead)):
        tile = w_eff[ti * tk:(ti + 1) * tk, tj * tn:(tj + 1) * tn]
        cols = stuck[tj * tn:(tj + 1) * tn][:tile.shape[1]]
        assert np.all(tile[:, cols != 2] == 0.0)
    # faults are permanent: recalibration leaves the masks untouched
    recal = devices.recalibrate({"l": dev_p}, jax.random.PRNGKey(1))
    assert np.array_equal(np.asarray(recal["l"]["device"]["stuck"]), stuck)
    assert np.array_equal(np.asarray(recal["l"]["device"]["dead"]), dead)


def test_recalibrate_restores_scale_and_restarts_clock():
    params, labels = _toy_site(seed=6)
    dp = devices.attach_device_state(params, labels, jax.random.PRNGKey(8),
                                     _DCFG)
    aged = devices.advance(dp, 168.0)
    err_aged = devices.health(aged)["mean_scale_err"]
    recal = devices.recalibrate(aged, jax.random.PRNGKey(2))
    err_recal = devices.health(recal)["mean_scale_err"]
    assert err_aged > 0.1 and err_recal < err_aged / 3
    d = recal["l"]["device"]
    # time doesn't rewind: t unchanged, t_prog caught up to it
    assert np.allclose(np.asarray(d["t"]), 168.0)
    assert np.allclose(np.asarray(d["t_prog"]), np.asarray(d["t"]))
    # drift exponents are physics, not calibration state
    assert np.array_equal(np.asarray(d["nu"]),
                          np.asarray(dp["l"]["device"]["nu"]))


@pytest.mark.parametrize("m,k,n", SHAPES_STRICT)
def test_device_fused_unfused_parity(m, k, n):
    """Per-tile corruption (scale + faults + drift offsets) must keep the
    fused Pallas path and the unfused reference on the shared ADC parity
    contract across the kernel shape grid — both consume the same
    materialized (w_eff, col_off), so parity is inherited."""
    dev_p, p, x = _device_site(m, k, n, seed=m * 7 + n)
    dev_p = devices.advance({"l": dev_p}, 12.0)["l"]
    y0, _ = analog_linear(dev_p, x, AnalogConfig(mode="analog"), EVAL)
    y1, _ = analog_linear(dev_p, x,
                          AnalogConfig(mode="analog", use_pallas=True),
                          EVAL)
    assert_adc_parity(y1, y0, _adc_lsb(p, 8))
    # the corruption is active: outputs differ from the pristine site
    y_clean, _ = analog_linear(p, x, AnalogConfig(mode="analog"), EVAL)
    assert not np.allclose(np.asarray(y0), np.asarray(y_clean))


@pytest.mark.parametrize("fused", [False, True], ids=["unfused", "fused"])
def test_null_device_state_is_bitwise_noop(fused):
    """All-zero sigmas/faults at dt=0 must leave the legacy analog path
    bitwise unchanged on both dispatch paths — attaching instrumentation
    can never move numerics."""
    null = devices.DeviceConfig(sigma_gain=0.0, nu_median=0.0,
                                nu_sigma=0.0, sigma_offset=0.0)
    dev_p, p, x = _device_site(5, 64, 48, seed=21, dcfg=null)
    cfg = AnalogConfig(mode="analog", use_pallas=fused)
    y_dev, _ = analog_linear(dev_p, x, cfg, EVAL)
    y_ref, _ = analog_linear(p, x, cfg, EVAL)
    assert np.array_equal(np.asarray(y_dev), np.asarray(y_ref))


def test_engine_drift_watchdog_recalibrates_in_flight():
    """Tiny serving smoke test: the drift clock ages the chip between
    steps, the watchdog reprograms mid-serve, and every request still
    completes (no KV/slot eviction on recalibration)."""
    from repro.configs import get_config
    from repro.models import build
    from repro.serve.scheduler import (Request, SchedulerConfig,
                                       ServeEngine)
    cfg = get_config("granite-3-8b").reduce()
    cfg, params, labels = build(cfg, jax.random.PRNGKey(0))
    dp = devices.attach_device_state(params, labels, jax.random.PRNGKey(7),
                                     _DCFG)
    scfg = SchedulerConfig(num_slots=2, max_len=48, prefill_chunk=8,
                           drift_dt=4.0, recalibrate=True,
                           recal_interval=2, recal_threshold=0.05)
    eng = ServeEngine(dp, cfg, AnalogConfig(mode="analog"), scfg)
    assert eng.drift_enabled and eng.recal_enabled
    rng = np.random.default_rng(1)
    reqs = [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab_size, 6 + i
                                        ).astype(np.int32),
                    max_new=10, temperature=0.0) for i in range(4)]
    res = eng.run(reqs)
    assert sorted(res) == [0, 1, 2, 3]
    assert all(len(res[i]) == 10 for i in res)
    assert eng.drift_hours > 0 and eng.recal_count >= 1
    assert eng.tile_scale_err < 0.1        # reprogrammed, not left to rot
    # honest gating: no device state -> drift/recal refuse with reasons
    bare = ServeEngine(params, cfg, AnalogConfig(mode="analog"), scfg)
    assert not bare.drift_enabled and not bare.recal_enabled
    assert "drift" in bare.gating_reasons
    assert "recalibrate" in bare.gating_reasons
