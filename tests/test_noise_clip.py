"""Noise-model and weight-clipping properties (paper eqs. 3–5, App. E.3).

Property tests skip (instead of breaking collection) when hypothesis is
absent — see tests/strategies.py / requirements-dev.txt.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from strategies import given, settings, st

from repro.core import clipping, noise
from repro.core.analog import noisy_matmul


@given(st.integers(0, 2**31 - 1), st.floats(0.005, 0.1))
@settings(max_examples=20, deadline=None)
def test_gaussian_noise_statistics(seed, gamma):
    key = jax.random.PRNGKey(seed)
    w = jax.random.normal(key, (256, 64)) * 0.1
    n = noise.gaussian_weight_noise(key, w, gamma)
    sigma_exp = gamma * np.abs(np.asarray(w)).max(axis=0)
    sigma_obs = np.asarray(n).std(axis=0)
    # per-channel std matches gamma * max|W_col| within sampling error
    assert np.allclose(sigma_obs, sigma_exp, rtol=0.35)


def test_pcm_sigma_polynomial_anchors():
    # noise floor at zero conductance, growth toward max
    s0 = float(noise.pcm_hermes_sigma(jnp.float32(0.0)))
    s100 = float(noise.pcm_hermes_sigma(jnp.float32(100.0)))
    assert s0 == pytest.approx(2.11, abs=1e-6)
    assert 7.0 < s100 < 9.0
    # monotone over most of the range (allow the fitted poly to wiggle)
    xs = np.linspace(0, 100, 101)
    ys = np.asarray(noise.pcm_hermes_sigma(jnp.asarray(xs, jnp.float32)))
    assert ys.min() >= 2.0


def test_pcm_noise_zero_weights_noiseless():
    key = jax.random.PRNGKey(0)
    w = jnp.zeros((32, 16)).at[0, 0].set(1.0)
    n = np.asarray(noise.pcm_hermes_noise(key, w))
    assert np.all(n[1:, :] == 0)
    assert np.all(n[:, 1:] == 0)
    assert n[0, 0] != 0


def test_pcm_noise_snr_ordering():
    """Bigger weights get more absolute noise but better relative SNR."""
    key = jax.random.PRNGKey(1)
    w = jnp.concatenate([jnp.full((4000, 1), 0.05), jnp.full((4000, 1), 1.0)],
                        axis=0)
    n = np.asarray(noise.pcm_hermes_noise(key, w))
    std_small = n[:4000].std()
    std_big = n[4000:].std()
    assert std_big > std_small                 # absolute noise grows
    assert std_big / 1.0 < std_small / 0.05    # relative noise shrinks


@given(st.integers(0, 2**31 - 1), st.floats(1.5, 4.0))
@settings(max_examples=20, deadline=None)
def test_clip_weight_bound(seed, alpha):
    key = jax.random.PRNGKey(seed)
    w = jax.random.normal(key, (128, 32))
    wc = np.asarray(clipping.clip_weight(w, alpha))
    std = np.asarray(w).std(axis=0)
    assert np.all(np.abs(wc) <= alpha * std + 1e-5)
    # clipping contracts: repeated clips keep shrinking toward 0 but each
    # pass moves less than the first (std shrinks monotonically)
    wcc = np.asarray(clipping.clip_weight(jnp.asarray(wc), alpha))
    assert np.abs(wcc).max() <= np.abs(wc).max() + 1e-6
    assert np.asarray(wcc).std() <= np.asarray(wc).std() + 1e-6


def test_clipping_reduces_kurtosis():
    key = jax.random.PRNGKey(2)
    # heavy-tailed weights (outliers)
    w = jax.random.t(key, df=3.0, shape=(4096,)).reshape(256, 16)
    k_before = float(clipping.kurtosis(w))
    wc = clipping.clip_weight(w, 3.0)
    k_after = float(clipping.kurtosis(wc))
    assert k_after < k_before          # Fig. 6b mechanism


def test_clip_tree_only_touches_analog_weights():
    params = {"a": {"kernel": jnp.ones((4, 4)) * 10,
                    "input_range": jnp.ones((1,))},
              "n": {"scale": jnp.ones((4,)) * 10}}
    labels = {"a": {"kernel": "analog_weight", "input_range": "input_range"},
              "n": {"scale": "digital"}}
    out = clipping.clip_tree(params, labels, alpha=2.0)
    assert float(jnp.max(out["n"]["scale"])) == 10.0
    assert float(jnp.max(out["a"]["kernel"])) < 10.0 or \
        float(jnp.std(params["a"]["kernel"])) == 0.0


def test_noisy_matmul_backward_uses_clean_weights():
    key = jax.random.PRNGKey(3)
    x = jax.random.normal(key, (8, 16))
    w = jax.random.normal(key, (16, 4))
    big_noise = jnp.ones_like(w) * 100.0

    def f(x, w):
        return jnp.sum(noisy_matmul(x, w, big_noise))

    gx, gw = jax.grad(f, argnums=(0, 1))(x, w)
    # dx must be g @ w.T with CLEAN w (noise-free backward, paper §3.1)
    expect_gx = jnp.ones((8, 4)) @ w.T
    np.testing.assert_allclose(np.asarray(gx), np.asarray(expect_gx),
                               rtol=1e-5)
    expect_gw = x.T @ jnp.ones((8, 4))
    np.testing.assert_allclose(np.asarray(gw), np.asarray(expect_gw),
                               rtol=1e-5)
